"""ServeEngine: continuous-batching inference over a slot-based KV cache.

The engine owns a fixed ``[max_slots, max_len]`` KV cache (one row per
in-flight sequence).  Admission is *continuous*: whenever a slot is free
and a request is queued, the request is prefilled — ONE jitted
full-sequence causal forward (``make_prefill_step(with_cache=True)``),
not a token-by-token replay — and its cache rows are packed into the free
slots *between* decode steps.  ``step()`` then runs one fused decode over
all occupied slots: every row appends and attends at its own length
(per-slot vector cache lengths, see ``models/blocks.py``), finished
sequences free their slot, and freed slots are refilled on the next step.
A static-batch baseline (``continuous=False``: admit only when every slot
is free) exists for the serving benchmark's comparison.

The engine is also a *service task body* for the pilot runtime
(``run_service``): driven through a :class:`~repro.core.task.ServiceControl`,
it pulls requests from the control inbox, and cooperates with priority
preemption — when the agent requests preemption it checkpoints its slot
state (cache, lengths, bound requests, queue), releases everything, and
raises :class:`~repro.core.task.ServicePreempted`; the agent re-queues the
task and the next attempt restores from the checkpoint and keeps serving.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.params import init_params, is_param
from repro.configs.base import ModelConfig, RunConfig
from repro.core.task import ServiceControl, ServicePreempted
from repro.models.lm import lm_cache_specs
from repro.serve.request import Request, RequestState
from repro.train.state import model_specs
from repro.train.step import make_decode_step, make_prefill_step


def _bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two >= n (floored at ``lo``) — bounds jit retraces."""
    p = lo
    while p < n:
        p *= 2
    return p


def _map_cache(fn_b0, fn_b1, *trees):
    """Map over LM cache trees, batch-axis aware: ``head_layers`` /
    ``tail_layers`` leaves are ``[batch, ...]`` (``fn_b0``) while the
    scanned ``unit`` leaves are ``[layers, batch, ...]`` (``fn_b1``)."""
    out = {k: jax.tree.map(fn_b0, *(t[k] for t in trees))
           for k in ("head_layers", "tail_layers") if k in trees[0]}
    if "unit" in trees[0]:
        out["unit"] = jax.tree.map(fn_b1, *(t["unit"] for t in trees))
    return out


class ServeEngine:
    """Slot-based continuous-batching engine for token-LM archs.

    Drive it either directly (``submit`` + ``step``/``run_until_drained``,
    the benchmark/test mode) or as a service stage under the pilot runtime
    (``run_service(control=...)``).
    """

    def __init__(self, cfg: ModelConfig, run_cfg: Optional[RunConfig] = None,
                 *, max_slots: int = 4, max_len: int = 128,
                 params: Any = None, seed: int = 0,
                 continuous: bool = True, idle_wait_s: float = 0.005):
        if cfg.is_encoder_decoder or cfg.input_kind != "tokens":
            raise NotImplementedError("ServeEngine targets token-LM archs")
        if cfg.mrope_sections:
            raise NotImplementedError(
                "M-RoPE position streams are not supported by the slot cache")
        if max_slots < 1 or max_len < 2:
            raise ValueError("need max_slots >= 1 and max_len >= 2")
        self.cfg = cfg
        self.run_cfg = run_cfg or RunConfig()
        self.max_slots = max_slots
        self.max_len = max_len
        self.continuous = continuous
        self.idle_wait_s = idle_wait_s
        self.params = (params if params is not None
                       else init_params(jax.random.PRNGKey(seed),
                                        model_specs(cfg)))
        # raises at construction for unsupported archs (recurrent caches)
        self._prefill = jax.jit(make_prefill_step(
            cfg, self.run_cfg, with_cache=True, max_len=max_len))
        decode = make_decode_step(cfg, self.run_cfg)

        def _step(params, tokens, cache, lengths, active):
            next_tok, _, new_cache = decode(params, tokens[:, None], cache,
                                            lengths)
            # freeze unoccupied slots: restore their cache rows so junk
            # writes never accumulate (also what keeps recurrent-style
            # state caches correct if they ever land here)
            def keep_b0(new, old):
                a = active.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(a, new, old)

            def keep_b1(new, old):  # scanned unit: [layers, batch, ...]
                a = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(a, new, old)

            return (jnp.where(active, next_tok, 0),
                    _map_cache(keep_b0, keep_b1, new_cache, cache))

        self._decode = jax.jit(_step, donate_argnums=(2,))

        def _pack(cache, rows, slot_idx):
            # copy freshly prefilled cache rows into their slots
            def set_b0(big, small):
                return big.at[slot_idx].set(small.astype(big.dtype),
                                            mode="drop")

            def set_b1(big, small):  # scanned unit: [layers, batch, ...]
                return big.at[:, slot_idx].set(small.astype(big.dtype),
                                               mode="drop")

            return _map_cache(set_b0, set_b1, cache, rows)

        self._pack = jax.jit(_pack, donate_argnums=(0,))

        self._lock = threading.Lock()
        self.queue: Deque[Request] = collections.deque()
        self.cache = None
        self.lengths = np.zeros(max_slots, np.int32)
        self.last_tok = np.zeros(max_slots, np.int32)
        self.slots: List[Optional[Request]] = [None] * max_slots
        self._stats: Dict[str, int] = collections.defaultdict(int)
        self._init_state()

    # -- state lifecycle -----------------------------------------------------

    def _init_state(self) -> None:
        specs = lm_cache_specs(self.cfg, self.max_slots, self.max_len)
        self.cache = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                  specs, is_leaf=is_param)
        self.lengths = np.zeros(self.max_slots, np.int32)
        self.last_tok = np.zeros(self.max_slots, np.int32)
        self.slots = [None] * self.max_slots

    def checkpoint(self) -> Dict[str, Any]:
        """Snapshot the full serving state (slot cache, per-slot lengths,
        bound requests, queued requests).  Cache arrays are copied so the
        snapshot survives later donated decode steps."""
        with self._lock:
            return {
                "cache": jax.tree.map(jnp.copy, self.cache),
                "lengths": self.lengths.copy(),
                "last_tok": self.last_tok.copy(),
                "slots": list(self.slots),
                "queue": list(self.queue),
                "stats": dict(self._stats),
            }

    def restore(self, state: Dict[str, Any]) -> None:
        with self._lock:
            # copy: the live cache is donated by decode/pack, and ``state``
            # may be the agent's stashed resume_state which a later retry
            # re-uses — aliasing it here would hand that retry deleted
            # buffers
            self.cache = jax.tree.map(jnp.copy, state["cache"])
            self.lengths = state["lengths"].copy()
            self.last_tok = state["last_tok"].copy()
            self.slots = list(state["slots"])
            self.queue = collections.deque(state["queue"])
            self._stats = collections.defaultdict(int, state["stats"])

    def _release_state(self) -> None:
        """Drop the live slot state (after checkpointing): the preempted
        engine holds no cache while higher-priority work runs."""
        with self._lock:
            self.cache = None
            self.slots = [None] * self.max_slots
            self.lengths = np.zeros(self.max_slots, np.int32)
            self.last_tok = np.zeros(self.max_slots, np.int32)
            self.queue = collections.deque()

    # -- client side ---------------------------------------------------------

    def submit(self, request, **kw) -> Request:
        """Queue a request (a :class:`Request` or a raw prompt array)."""
        if not isinstance(request, Request):
            request = Request(np.asarray(request, np.int32), **kw)
        with self._lock:
            self.queue.append(request)
        return request

    def has_work(self) -> bool:
        with self._lock:
            return bool(self.queue) or any(r is not None for r in self.slots)

    def occupancy(self) -> int:
        return sum(r is not None for r in self.slots)

    # -- engine core ---------------------------------------------------------

    def _finish_slot(self, i: int, state: RequestState,
                     error: Optional[str] = None) -> None:
        req = self.slots[i]
        self.slots[i] = None
        self.lengths[i] = 0
        self.last_tok[i] = 0
        req._finish(state, error)
        self._stats["completed" if state is RequestState.DONE else "failed"] += 1

    def _fail_outstanding(self, error: str) -> None:
        """Terminate every accepted-but-unfinished request (hard stop):
        waiters block on Request.wait(), so abandoning them silently would
        hang clients forever."""
        for i, req in enumerate(self.slots):
            if req is not None:
                self._finish_slot(i, RequestState.FAILED, error)
        with self._lock:
            queued, self.queue = list(self.queue), collections.deque()
        for req in queued:
            req._finish(RequestState.FAILED, error)
            self._stats["failed"] += 1

    def _should_stop(self, req: Request, tok: int, length: int) -> bool:
        return (len(req.tokens) >= req.max_new_tokens
                or (req.stop_token is not None and tok == req.stop_token)
                or length >= self.max_len)

    def _admit(self) -> int:
        """Pack queued requests into free slots via batched prefill.
        Returns the number admitted this call."""
        free = [i for i, r in enumerate(self.slots) if r is None]
        with self._lock:
            if not free or not self.queue:
                return 0
            if not self.continuous and len(free) < self.max_slots:
                return 0  # static batching: wait for the whole batch to end
            batch: List[Request] = []
            while self.queue and len(batch) < len(free):
                req = self.queue.popleft()
                if req.prompt_len > self.max_len - 1:
                    req._finish(RequestState.FAILED,
                                f"prompt ({req.prompt_len} tokens) does not "
                                f"fit max_len={self.max_len}")
                    self._stats["failed"] += 1
                    continue
                batch.append(req)
        if not batch:
            return 0
        nb = len(batch)
        # bucket both prefill dims to powers of two so jit retraces stay
        # bounded; padding rows carry slot index max_slots, which the
        # drop-mode pack discards
        nbp = _bucket(nb, lo=1)
        P = min(_bucket(max(r.prompt_len for r in batch)), self.max_len)
        tokens = np.zeros((nbp, P), np.int32)
        lens = np.zeros(nbp, np.int32)
        slot_idx = np.full(nbp, self.max_slots, np.int32)
        for j, req in enumerate(batch):
            tokens[j, :req.prompt_len] = req.prompt
            lens[j] = req.prompt_len
            slot_idx[j] = free[j]
        next_tok, _, rows = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lens))
        self.cache = self._pack(self.cache, rows, jnp.asarray(slot_idx))
        toks = np.asarray(next_tok)
        now = time.time()
        for j, req in enumerate(batch):
            i = free[j]
            self.slots[i] = req
            self.lengths[i] = req.prompt_len
            req.state = RequestState.RUNNING
            req.admitted_at = now
            req.first_token_at = now
            tok = int(toks[j])
            req.tokens.append(tok)
            self.last_tok[i] = tok
            if self._should_stop(req, tok, int(self.lengths[i])):
                self._finish_slot(i, RequestState.DONE)
        self._stats["admitted"] += nb
        self._stats["prefill_batches"] += 1
        self._stats["prefill_tokens"] += int(lens.sum())
        return nb

    def step(self) -> bool:
        """Admit what fits, then run one fused decode over every occupied
        slot.  Returns False when there was nothing to do."""
        progressed = self._admit() > 0
        active = np.array([r is not None for r in self.slots])
        if not active.any():
            return progressed
        next_tok, self.cache = self._decode(
            self.params, jnp.asarray(self.last_tok), self.cache,
            jnp.asarray(self.lengths), jnp.asarray(active))
        toks = np.asarray(next_tok)
        self.lengths = self.lengths + active.astype(np.int32)
        self._stats["decode_steps"] += 1
        self._stats["decode_slot_steps"] += int(active.sum())
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(toks[i])
            req.tokens.append(tok)
            self.last_tok[i] = tok
            self._stats["tokens_generated"] += 1
            if self._should_stop(req, tok, int(self.lengths[i])):
                self._finish_slot(i, RequestState.DONE)
        return True

    def run_until_drained(self, max_steps: int = 100_000) -> None:
        """Synchronous drive: step until queue and slots are empty."""
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} steps")

    # -- service-stage body --------------------------------------------------

    def run_service(self, control: Optional[ServiceControl] = None,
                    resume_state: Any = None) -> Dict[str, Any]:
        """Long-running service loop (the body of a ``service=True`` stage).

        Pulls requests from the control inbox, steps the engine, and
        cooperates with the runtime: ``stop()`` exits immediately,
        ``drain()`` exits once every accepted request finished, and a
        preemption request checkpoints + yields via ServicePreempted.
        """
        if resume_state is not None:
            self.restore(resume_state)
            self._stats["resumes"] += 1
        if self.cache is None:
            self._init_state()
        while True:
            if control is not None:
                for req in control.take_requests():
                    self.submit(req)
                if control.stop_requested():
                    # hard stop: sweep any request that raced in after the
                    # take above, then fail everything outstanding so
                    # Request.wait() callers are released, not hung
                    for req in control.take_requests():
                        self.submit(req)
                    self._fail_outstanding("service stopped before completion")
                    break
                if control.preempt_requested():
                    self._stats["preemptions"] += 1  # before the snapshot
                    # so the count survives restore()
                    state = self.checkpoint()
                    self._release_state()
                    raise ServicePreempted(state)
            if not self.step():
                if control is None:
                    break
                if (control.drain_requested()
                        and control.pending_requests() == 0):
                    break
                control.wait_for_work(self.idle_wait_s)
        return self.stats()

    # -- reporting -----------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        out = dict(self._stats)
        out.update({
            "max_slots": self.max_slots,
            "max_len": self.max_len,
            "continuous": self.continuous,
            "queued": len(self.queue),
            "occupied": self.occupancy(),
        })
        d = out.get("decode_steps", 0)
        out["slot_occupancy"] = (
            out.get("decode_slot_steps", 0) / (d * self.max_slots)
            if d else 0.0)
        return out

    def reset_stats(self) -> None:
        self._stats = collections.defaultdict(int)
