"""ServeEngine: continuous-batching inference over a paged KV cache.

The engine owns a shared **page pool** per layer (``[num_pages,
page_size, ...]``) plus a per-slot **block table** (``[max_slots,
max_pages] int32``, vLLM-style): a sequence's KV lives in whatever
physical pages its table points at, so ``max_slots x max_len`` can
exceed the physically backed cache (set ``num_pages`` below the
full-backing default to overcommit).  Admission allocates pages on
demand from a free list, prefill writes page-aligned chunks straight
into the pool, ``_finish_slot`` returns a sequence's pages to the free
list, and the decode step gathers K/V through the block table inside the
flash-decode kernel (``kernels/ops.decode_attention_paged``) — the grid
is bucketed to the pages actually in use, so short sequences never pay
for ``max_len``.  ``kv_layout="contiguous"`` keeps the PR-3 layout (one
``[max_slots, max_len]`` row per slot, vector-length kernel) as the
benchmark baseline.

Admission is *continuous*: whenever a slot is free and a request is
queued, the request binds to the slot and its pages are reserved;
prefill then proceeds in **bounded chunks** interleaved with decode
(Sarathi/vLLM-style chunked prefill).  Each ``step()`` spends at most
``prefill_chunk_tokens`` prompt tokens across the currently-prefilling
slots — one jitted ragged cache-writing forward
(``make_prefill_chunk_step``, the prefill kernel in
``kernels/prefill_attention.py``) appends every row's chunk at its own
offset straight into the pool/slot cache — and then runs one fused
decode over the slots whose prefill already finished.  A long prompt
therefore stalls in-flight decode tails by at most one chunk per step
instead of its whole length, which is what bounds the inter-token stall
tail (each request's worst gap, the global p99) under mixed long/short
workloads.  This retires the old
whole-prompt prefill scratch (``[nb, prompt_bucket]`` rows packed into
pages after the fact) and the unbounded per-prompt-bucket jit cache: the
chunk step writes in place, and its jitted variants are keyed by chunk
bucket in a small LRU (``prefill_fns_cached`` in ``stats()``).
Sampling is per-slot (temperature / top-k / seeded PRNG streams; greedy
default is bit-identical to argmax), finished sequences free their slot
and pages, and freed capacity is refilled on the next step.  A
static-batch baseline (``continuous=False``: admit only when every slot
is free) exists for the serving benchmark's comparison; passing
``prefill_chunk_tokens=None`` keeps admission whole-prompt (one chunk
covers the prompt) as the chunking baseline.

The engine is also a *service task body* for the pilot runtime
(``run_service``): driven through a :class:`~repro.core.task.ServiceControl`,
it pulls requests from the control inbox, and cooperates with priority
preemption — when the agent requests preemption it checkpoints its slot
state (page pool, block tables, free list, per-slot PRNG keys, bound
requests, queue), releases everything, and raises
:class:`~repro.core.task.ServicePreempted`; the agent re-queues the task
and the next attempt restores from the checkpoint and keeps serving.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.params import init_params, is_param
from repro.configs.base import ModelConfig, RunConfig
from repro.core.resilience import faults as rfaults
from repro.core.task import ServiceControl, ServicePreempted
from repro.models.lm import lm_cache_specs, lm_paged_cache_specs
from repro.serve.handoff import KVHandoff
from repro.serve.request import Request, RequestState
from repro.serve.sampling import make_slot_key, sample_tokens
from repro.train.state import model_specs
from repro.train.step import make_decode_step, make_prefill_chunk_step

_engine_uid = itertools.count()


def _entry_submitted_at(entry) -> float:
    """Submission time of a queue entry (Request or migrated KVHandoff)."""
    return (entry.request.submitted_at if isinstance(entry, KVHandoff)
            else entry.submitted_at)


def _bucket(n: int, lo: int = 2) -> int:
    """Next power-of-two >= n (floored at ``lo``) — bounds jit retraces.
    The floor is 2, not 8: with 1-2 occupied prefill rows an 8-floor pads
    every admission to batch 8; the engine counts actual retraces in
    ``stats()`` so the bucketing/retrace tradeoff stays observable."""
    p = lo
    while p < n:
        p *= 2
    return p


def _map_cache(fn_b0, fn_b1, *trees):
    """Map over LM cache trees, batch-axis aware: ``head_layers`` /
    ``tail_layers`` leaves are ``[batch, ...]`` (``fn_b0``) while the
    scanned ``unit`` leaves are ``[layers, batch, ...]`` (``fn_b1``)."""
    out = {k: jax.tree.map(fn_b0, *(t[k] for t in trees))
           for k in ("head_layers", "tail_layers") if k in trees[0]}
    if "unit" in trees[0]:
        out["unit"] = jax.tree.map(fn_b1, *(t["unit"] for t in trees))
    return out


def _tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


class ServeEngine:
    """Paged continuous-batching engine for token-LM archs.

    Drive it either directly (``submit`` + ``step``/``run_until_drained``,
    the benchmark/test mode) or as a service stage under the pilot runtime
    (``run_service(control=...)``).
    """

    # jitted chunk-step variants kept per chunk bucket; small because the
    # chunk budget bounds the bucket count to log2(budget) + 1
    _PREFILL_FN_CAP = 8

    def __init__(self, cfg: ModelConfig, run_cfg: Optional[RunConfig] = None,
                 *, max_slots: int = 4, max_len: int = 128,
                 params: Any = None, seed: int = 0,
                 continuous: bool = True, idle_wait_s: float = 0.005,
                 kv_layout: str = "paged", page_size: int = 16,
                 num_pages: Optional[int] = None,
                 decode_impl: Optional[str] = None,
                 prefill_chunk_tokens: Optional[int] = 64,
                 prefill_only: bool = False,
                 name: Optional[str] = None):
        if cfg.is_encoder_decoder or cfg.input_kind != "tokens":
            raise NotImplementedError("ServeEngine targets token-LM archs")
        if cfg.mrope_sections:
            raise NotImplementedError(
                "M-RoPE position streams are not supported by the slot cache")
        if max_slots < 1 or max_len < 2:
            raise ValueError("need max_slots >= 1 and max_len >= 2")
        if kv_layout not in ("paged", "contiguous"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if prefill_only and kv_layout != "paged":
            raise ValueError("prefill_only engines require kv_layout="
                             "'paged' (handoff ships page blocks)")
        if decode_impl is not None:
            cfg = cfg.with_overrides(decode_impl=decode_impl)
        self.cfg = cfg
        self.run_cfg = run_cfg or RunConfig()
        self.uid = name or f"engine{next(_engine_uid):03d}"
        # prefill-specialised role: finished prompts are exported as
        # KVHandoff page blocks instead of decoding in place
        self.prefill_only = prefill_only
        self.max_slots = max_slots
        self.max_len = max_len
        self.continuous = continuous
        self.idle_wait_s = idle_wait_s
        if prefill_chunk_tokens is not None and prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1 (or None "
                             "for whole-prompt prefill)")
        self.paged = kv_layout == "paged"
        self.page_size = page_size
        self.max_pages = -(-max_len // page_size)
        # per-step prompt-token budget for chunked prefill; None = each
        # prompt prefills in one chunk (the unchunked baseline)
        self.prefill_chunk_tokens = prefill_chunk_tokens
        # full backing by default; pass a smaller num_pages to overcommit
        # (max_slots x max_len of *logical* capacity over fewer physical
        # pages — admission backpressures on the free list)
        self.num_pages = (num_pages if num_pages is not None
                          else max_slots * self.max_pages)
        self.params = (params if params is not None
                       else init_params(jax.random.PRNGKey(seed),
                                        model_specs(cfg)))
        if self.paged:
            # raises at construction for unsupported archs: paged caches
            # need attention-family temporal blocks
            lm_paged_cache_specs(cfg, 1, page_size)
        # raises at construction for archs the ragged chunked prefill
        # cannot serve (recurrent state caches, windowed ring caches)
        self._prefill_chunk = make_prefill_chunk_step(cfg, self.run_cfg)
        # chunk-bucket -> jitted chunk step, LRU-capped (satellite of the
        # old unbounded per-prompt-bucket cache this replaced)
        self._prefill_fns: "collections.OrderedDict[int, Any]" = (
            collections.OrderedDict())
        decode = make_decode_step(cfg, self.run_cfg)
        self._sample = jax.jit(sample_tokens)

        # ``sampling`` is a static flag: an all-greedy batch (the default)
        # keeps the old argmax-only hot path — no full-vocab sort, no
        # Gumbel draws, no key advancement.  Greedy slots never consume
        # their keys, so skipping the sampler when no occupied slot
        # samples cannot change any stream.
        if self.paged:

            def _step(params, tokens, cache, lengths, active, keys, temps,
                      topks, block_table, *, sampling):
                greedy, logits, new_cache = decode(
                    params, tokens[:, None], cache, lengths, block_table)
                if sampling:
                    toks, new_keys = sample_tokens(logits[:, -1], keys,
                                                   temps, topks)
                else:
                    toks, new_keys = greedy, keys
                # inactive slots: their block-table rows are all-sentinel,
                # so their junk appends already dropped inside the kernel
                return (jnp.where(active, toks, 0),
                        jnp.where(active[:, None], new_keys, keys),
                        new_cache)

        else:

            def _step(params, tokens, cache, lengths, active, keys, temps,
                      topks, *, sampling):
                greedy, logits, new_cache = decode(params, tokens[:, None],
                                                   cache, lengths)
                if sampling:
                    toks, new_keys = sample_tokens(logits[:, -1], keys,
                                                   temps, topks)
                else:
                    toks, new_keys = greedy, keys

                # freeze unoccupied slots: restore their cache rows so junk
                # writes never accumulate
                def keep_b0(new, old):
                    a = active.reshape((-1,) + (1,) * (new.ndim - 1))
                    return jnp.where(a, new, old)

                def keep_b1(new, old):  # scanned unit: [layers, batch, ...]
                    a = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                    return jnp.where(a, new, old)

                return (jnp.where(active, toks, 0),
                        jnp.where(active[:, None], new_keys, keys),
                        _map_cache(keep_b0, keep_b1, new_cache, cache))

        self._decode = jax.jit(_step, donate_argnums=(2,),
                               static_argnames=("sampling",))

        # _lock guards the state shared with submitter/monitor threads
        # (queue, stats, retrace tracking).  The slot/page fields below
        # (cache, lengths, slots, free_pages, slot_pages, block_table, ...)
        # are owned by the engine thread that calls step(); checkpoint()/
        # restore()/_release_state() snapshot them under _lock.
        self._lock = threading.Lock()
        self.queue: Deque[Any] = collections.deque()  # guarded-by: _lock
        # finished prefills parked for the router's handoff mover
        self._outbox: Deque[KVHandoff] = collections.deque()  # guarded-by: _lock
        self.cache = None
        self.lengths = np.zeros(max_slots, np.int32)
        self.last_tok = np.zeros(max_slots, np.int32)
        self.slots: List[Optional[Request]] = [None] * max_slots
        self._stats: Dict[str, int] = collections.defaultdict(int)  # guarded-by: _lock
        self._seen_shapes: Dict[str, set] = collections.defaultdict(set)  # guarded-by: _lock
        self._init_state()
        self._page_bytes = 0
        self._cache_bytes = _tree_bytes(self.cache)
        if self.paged:
            self._page_bytes = self._cache_bytes // self.num_pages

    # -- state lifecycle -----------------------------------------------------

    def _init_state(self) -> None:
        if self.paged:
            specs = lm_paged_cache_specs(self.cfg, self.num_pages,
                                         self.page_size)
            # per-slot block tables; sentinel num_pages = unallocated
            self.block_table = np.full((self.max_slots, self.max_pages),
                                       self.num_pages, np.int32)
            self.free_pages: List[int] = list(range(self.num_pages))
            self.slot_pages: List[List[int]] = [[] for _ in
                                                range(self.max_slots)]
        else:
            specs = lm_cache_specs(self.cfg, self.max_slots, self.max_len)
        self.cache = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                  specs, is_leaf=is_param)
        self.lengths = np.zeros(self.max_slots, np.int32)
        self.last_tok = np.zeros(self.max_slots, np.int32)
        self.slots = [None] * self.max_slots
        self.slot_keys = np.zeros((self.max_slots, 2), np.uint32)
        self.slot_temp = np.zeros(self.max_slots, np.float32)
        self.slot_topk = np.zeros(self.max_slots, np.int32)
        # chunked-prefill progress: tokens of the prompt already written
        # into the cache, or -1 once the slot is decoding / free
        self.prefill_pos = np.full(self.max_slots, -1, np.int32)
        self.slot_prompt: List[Optional[np.ndarray]] = (
            [None] * self.max_slots)

    def checkpoint(self) -> Dict[str, Any]:
        """Snapshot the full serving state (page pool + block tables +
        free list for paged, slot cache otherwise; per-slot lengths and
        sampling PRNG keys; bound and queued requests).  Cache arrays are
        copied so the snapshot survives later donated decode steps."""
        with self._lock:
            state = {
                "cache": jax.tree.map(jnp.copy, self.cache),
                "lengths": self.lengths.copy(),
                "last_tok": self.last_tok.copy(),
                "slots": list(self.slots),
                "queue": list(self.queue),
                "outbox": list(self._outbox),
                "stats": dict(self._stats),
                "slot_keys": self.slot_keys.copy(),
                "slot_temp": self.slot_temp.copy(),
                "slot_topk": self.slot_topk.copy(),
                "prefill_pos": self.prefill_pos.copy(),
                "slot_prompt": list(self.slot_prompt),
            }
            if self.paged:
                state.update({
                    "block_table": self.block_table.copy(),
                    "free_pages": list(self.free_pages),
                    "slot_pages": [list(p) for p in self.slot_pages],
                })
            return state

    def restore(self, state: Dict[str, Any]) -> None:
        with self._lock:
            # copy: the live cache is donated by decode/pack, and ``state``
            # may be the agent's stashed resume_state which a later retry
            # re-uses — aliasing it here would hand that retry deleted
            # buffers
            self.cache = jax.tree.map(jnp.copy, state["cache"])
            self.lengths = state["lengths"].copy()
            self.last_tok = state["last_tok"].copy()
            self.slots = list(state["slots"])
            self.queue = collections.deque(state["queue"])
            self._outbox = collections.deque(state.get("outbox", ()))
            self._stats = collections.defaultdict(int, state["stats"])
            self.slot_keys = state["slot_keys"].copy()
            self.slot_temp = state["slot_temp"].copy()
            self.slot_topk = state["slot_topk"].copy()
            self.prefill_pos = state["prefill_pos"].copy()
            self.slot_prompt = list(state["slot_prompt"])
            if self.paged:
                self.block_table = state["block_table"].copy()
                self.free_pages = list(state["free_pages"])
                self.slot_pages = [list(p) for p in state["slot_pages"]]

    def _release_state(self) -> None:
        """Drop the live slot state (after checkpointing): the preempted
        engine holds no cache while higher-priority work runs."""
        with self._lock:
            self.cache = None
            self.slots = [None] * self.max_slots
            self.lengths = np.zeros(self.max_slots, np.int32)
            self.last_tok = np.zeros(self.max_slots, np.int32)
            self.queue = collections.deque()
            self._outbox = collections.deque()
            self.slot_keys = np.zeros((self.max_slots, 2), np.uint32)
            self.slot_temp = np.zeros(self.max_slots, np.float32)
            self.slot_topk = np.zeros(self.max_slots, np.int32)
            self.prefill_pos = np.full(self.max_slots, -1, np.int32)
            self.slot_prompt = [None] * self.max_slots
            if self.paged:
                self.block_table = np.full(
                    (self.max_slots, self.max_pages), self.num_pages,
                    np.int32)
                self.free_pages = list(range(self.num_pages))
                self.slot_pages = [[] for _ in range(self.max_slots)]

    # -- client side ---------------------------------------------------------

    def submit(self, request, **kw) -> Request:
        """Queue a request (a :class:`Request`, a raw prompt array, or a
        migrated :class:`KVHandoff` from a prefill engine)."""
        if isinstance(request, KVHandoff):
            if not self.paged:
                raise ValueError(
                    "KVHandoff import needs a paged engine")
            if request.page_size != self.page_size:
                raise ValueError(
                    f"handoff page_size {request.page_size} != engine "
                    f"page_size {self.page_size}")
            with self._lock:
                self.queue.append(request)
            return request.request
        if not isinstance(request, Request):
            request = Request(np.asarray(request, np.int32), **kw)
        with self._lock:
            self.queue.append(request)
        return request

    def take_handoffs(self) -> List[KVHandoff]:
        """Pop every exported prefill (the router's handoff mover ships
        these through the transport into a decode engine)."""
        with self._lock:
            out = list(self._outbox)
            self._outbox.clear()
        return out

    def steal_queued(self) -> List[Any]:
        """Pop every queued-but-unbound entry so a router can re-route
        it away from a draining or preempted engine.  Bound slots are
        not touched — they finish here or ride the preemption
        checkpoint."""
        with self._lock:
            out = list(self.queue)
            self.queue.clear()
        return out

    def recover_outstanding(self) -> List[Any]:
        """Crash recovery (the router's circuit-breaker path): collect
        every accepted-but-unfinished entry — bound slots, queued
        entries, parked handoffs — and return them for re-routing
        instead of failing them.  Bound requests lose their in-pool KV
        with the crashed state, so they are reset to QUEUED and
        re-enter as plain prompts (:meth:`Request.reset_for_retry`
        documents why the regenerated output is reproducible).  Queued
        entries and exported handoffs return as-is — a handoff's page
        blocks are host-side copies independent of the dead engine
        state.  The slot state is released; the next ``run_service``
        starts fresh."""
        with self._lock:
            bound = [r for r in self.slots if r is not None]
            queued, self.queue = list(self.queue), collections.deque()
            handed, self._outbox = list(self._outbox), collections.deque()
        for req in bound:
            if not req.done():
                req.reset_for_retry()
        self._release_state()
        recovered = bound + queued + handed
        if recovered:
            self._bump("recovered", len(recovered))
        return recovered

    def has_work(self) -> bool:
        with self._lock:
            return bool(self.queue) or any(r is not None for r in self.slots)

    def occupancy(self) -> int:
        with self._lock:  # cross-thread monitoring read
            return sum(r is not None for r in self.slots)

    def pages_in_use(self) -> int:
        with self._lock:  # cross-thread monitoring read
            return self.num_pages - len(self.free_pages) if self.paged else 0

    def admission_signals(self) -> Dict[str, Any]:
        """One-lock snapshot of the signals a fleet router admits on:
        slot occupancy, page-pool pressure, and queue depth/age.  For
        contiguous engines the page figures degrade to free slots (each
        slot owns its full row, so slots are the only capacity axis)."""
        with self._lock:
            now = time.time()
            occupied = sum(r is not None for r in self.slots)
            return {
                "engine": self.uid,
                "prefill_only": self.prefill_only,
                "occupied": occupied,
                "max_slots": self.max_slots,
                "queue_depth": len(self.queue),
                "oldest_queued_age_s": (
                    now - min(_entry_submitted_at(e) for e in self.queue)
                    if self.queue else 0.0),
                "free_pages": (len(self.free_pages) if self.paged
                               else self.max_slots - occupied),
                "num_pages": (self.num_pages if self.paged
                              else self.max_slots),
            }

    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._stats[key] += n

    # -- page bookkeeping ----------------------------------------------------

    def _count_retrace(self, kind: str, key) -> None:
        with self._lock:
            seen = self._seen_shapes[kind]
            if key not in seen:
                seen.add(key)
                self._stats["retraces"] += 1
                self._stats[f"retraces_{kind}"] += 1

    def _alloc_pages(self, slot: int, n: int) -> bool:
        """Append ``n`` fresh pages to a slot's block table (False if the
        pool cannot supply them — caller backpressures or fails)."""
        if len(self.free_pages) < n:
            return False
        base = len(self.slot_pages[slot])
        if base + n > self.max_pages:
            return False
        for j in range(n):
            pid = self.free_pages.pop()
            self.slot_pages[slot].append(pid)
            self.block_table[slot, base + j] = pid
        used = self.pages_in_use()
        with self._lock:
            if used > self._stats.get("peak_pages", 0):
                self._stats["peak_pages"] = used
        return True

    def _free_slot_pages(self, slot: int) -> None:
        self.free_pages.extend(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.block_table[slot, :] = self.num_pages

    def _ensure_decode_pages(self) -> None:
        """Every active slot appends K/V at position ``lengths[i]`` this
        step — allocate the covering page if the sequence just crossed a
        page boundary.  A slot the pool cannot serve fails (its own pages
        return to the free list, which may unblock the remaining slots).
        Slots still prefilling are skipped: their prompt pages were
        reserved at admission and they do not decode yet."""
        for i, req in enumerate(self.slots):
            if req is None or self.prefill_pos[i] >= 0:
                continue
            lp = int(self.lengths[i]) // self.page_size
            if lp < len(self.slot_pages[i]):
                continue
            if not self._alloc_pages(i, 1):
                self._finish_slot(
                    i, RequestState.FAILED,
                    f"page pool exhausted ({self.num_pages} pages of "
                    f"{self.page_size}); lower the load or raise num_pages")

    # -- engine core ---------------------------------------------------------

    def _finish_slot(self, i: int, state: RequestState,
                     error: Optional[str] = None) -> None:
        req = self.slots[i]
        self.slots[i] = None
        self.lengths[i] = 0
        self.last_tok[i] = 0
        self.slot_temp[i] = 0.0
        self.slot_topk[i] = 0
        self.slot_keys[i] = 0
        self.prefill_pos[i] = -1
        self.slot_prompt[i] = None
        if self.paged:
            self._free_slot_pages(i)
        req._finish(state, error)
        self._bump("completed" if state is RequestState.DONE else "failed")

    def _pad_pids(self, pids: np.ndarray) -> np.ndarray:
        """Pad a page-id list to its power-of-two bucket by repeating the
        last id: the gather/scatter XLA shapes stay bounded to
        ``log2(max_pages) + 1`` variants instead of one per distinct page
        count (an eager compile inside the serving hot path otherwise).
        Duplicate ids are safe — every duplicate carries the same block,
        so scatter order cannot change the result."""
        b = min(_bucket(max(len(pids), 1), lo=1), self.max_pages)
        if b == len(pids):
            return pids
        return np.concatenate(
            [pids, np.full(b - len(pids), pids[-1], np.int32)])

    def _export_slot(self, i: int) -> None:
        """Prefill-only handoff: gather exactly the slot's own pages out
        of the pool (a block copy addressed by the block-table row — the
        pool itself never ships) and park them in the outbox as a
        :class:`KVHandoff`.  The slot unbinds WITHOUT finishing the
        request: it stays RUNNING and completes on the importing decode
        engine."""
        req = self.slots[i]
        pids = np.asarray(self.slot_pages[i], np.int32)
        n = len(pids)
        padded = jnp.asarray(self._pad_pids(pids))
        self._count_retrace("handoff_gather", int(padded.shape[0]))
        # gather at the bucketed width, ship only the owned pages
        pages = _map_cache(lambda l: np.asarray(l[padded])[:n],
                           lambda l: np.asarray(l[:, padded])[:, :n],
                           self.cache)
        hand = KVHandoff(
            request=req, length=int(self.lengths[i]),
            last_tok=int(self.last_tok[i]),
            slot_key=self.slot_keys[i].copy(),
            temperature=float(self.slot_temp[i]),
            top_k=int(self.slot_topk[i]), pages=pages,
            n_pages=len(self.slot_pages[i]), page_size=self.page_size,
            kv_bytes=_tree_bytes(pages), source=self.uid)
        self.slots[i] = None
        self.lengths[i] = 0
        self.last_tok[i] = 0
        self.slot_temp[i] = 0.0
        self.slot_topk[i] = 0
        self.slot_keys[i] = 0
        self.prefill_pos[i] = -1
        self.slot_prompt[i] = None
        self._free_slot_pages(i)
        with self._lock:
            self._outbox.append(hand)
            self._stats["handoffs_exported"] += 1
            self._stats["handoff_bytes_exported"] += hand.kv_bytes

    def _fail_outstanding(self, error: str) -> None:
        """Terminate every accepted-but-unfinished request (hard stop):
        waiters block on Request.wait(), so abandoning them silently would
        hang clients forever."""
        for i, req in enumerate(self.slots):
            if req is not None:
                self._finish_slot(i, RequestState.FAILED, error)
        with self._lock:
            queued, self.queue = list(self.queue), collections.deque()
            handed, self._outbox = list(self._outbox), collections.deque()
        for entry in queued + handed:
            # _finish runs callbacks — keep it outside the lock
            req = entry.request if isinstance(entry, KVHandoff) else entry
            req._finish(RequestState.FAILED, error)
        if queued or handed:
            self._bump("failed", len(queued) + len(handed))

    def _should_stop(self, req: Request, tok: int, length: int) -> bool:
        return (len(req.tokens) >= req.max_new_tokens
                or (req.stop_token is not None and tok == req.stop_token)
                or length >= self.max_len)

    def _get_prefill(self, chunk_t: int):
        """Jitted chunk-step per chunk bucket, LRU-capped at
        ``_PREFILL_FN_CAP`` — evicting an entry drops its whole compiled
        family (the paged page-bucket variants live inside one entry's
        jit cache).  The chunk budget bounds live buckets to
        ``log2(budget) + 1``, so eviction only fires when callers mix
        many chunk settings on one engine."""
        fn = self._prefill_fns.get(chunk_t)
        if fn is None:
            fn = jax.jit(self._prefill_chunk, donate_argnums=(4,))
            self._prefill_fns[chunk_t] = fn
            if len(self._prefill_fns) > self._PREFILL_FN_CAP:
                self._prefill_fns.popitem(last=False)
                self._bump("prefill_fns_evicted")
        else:
            self._prefill_fns.move_to_end(chunk_t)
        return fn

    def _admit(self) -> int:
        """Bind queued requests to free slots (reserving their prompt
        pages); the actual prompt processing happens chunk-by-chunk in
        ``_prefill_step``.  Returns the number admitted this call."""
        free = [i for i, r in enumerate(self.slots) if r is None]
        with self._lock:
            if not free or not self.queue:
                return 0
            if not self.continuous and len(free) < self.max_slots:
                return 0  # static batching: wait for the whole batch to end
            batch: List[Any] = []
            reserved = 0
            while self.queue and len(batch) < len(free):
                req = self.queue[0]
                if isinstance(req, KVHandoff):
                    # migrated prefill: its own pages plus one
                    # decode-growth page (same rule as a fresh prompt)
                    need = min(req.n_pages + 1, self.max_pages)
                    if need > self.num_pages:
                        self.queue.popleft()
                        req.request._finish(
                            RequestState.FAILED,
                            f"handoff needs {need} pages of "
                            f"{self.page_size} but the pool only has "
                            f"{self.num_pages}")
                        self._stats["failed"] += 1
                        continue
                    if reserved + need > len(self.free_pages):
                        break  # FIFO backpressure, same as prompts
                    reserved += need
                    batch.append(self.queue.popleft())
                    continue
                if req.prompt_len > self.max_len - 1:
                    self.queue.popleft()
                    req._finish(RequestState.FAILED,
                                f"prompt ({req.prompt_len} tokens) does not "
                                f"fit max_len={self.max_len}")
                    self._stats["failed"] += 1
                    continue
                if self.paged:
                    # reserve the prompt's pages plus one decode-growth
                    # page (capped at what the sequence can ever address)
                    need = min(-(-req.prompt_len // self.page_size) + 1,
                               self.max_pages)
                    if need > self.num_pages:
                        # no amount of recycling can ever serve this
                        # request — fail it now, or it livelocks the
                        # whole FIFO queue behind it
                        self.queue.popleft()
                        req._finish(
                            RequestState.FAILED,
                            f"prompt needs {need} pages of "
                            f"{self.page_size} but the pool only has "
                            f"{self.num_pages}")
                        self._stats["failed"] += 1
                        continue
                    if reserved + need > len(self.free_pages):
                        # transient shortage: FIFO backpressure — the
                        # head waits for pages to recycle rather than
                        # being skipped
                        break
                    reserved += need
                batch.append(self.queue.popleft())
        if not batch:
            return 0
        nb = len(batch)
        now = time.time()
        for j, req in enumerate(batch):
            i = free[j]
            if isinstance(req, KVHandoff):
                self._import_handoff(i, req, now)
                continue
            if self.paged:
                n_pages = -(-req.prompt_len // self.page_size)
                if not self._alloc_pages(i, n_pages):
                    raise RuntimeError(
                        "page reservation failed after admission check")
            self.slots[i] = req
            self.lengths[i] = 0  # becomes prompt_len when prefill finishes
            self.prefill_pos[i] = 0
            self.slot_prompt[i] = np.asarray(req.prompt, np.int32)
            self.slot_keys[i] = make_slot_key(req.seed)
            self.slot_temp[i] = req.temperature
            self.slot_topk[i] = req.top_k
            req.state = RequestState.RUNNING
            req.admitted_at = now
        with self._lock:
            self._stats["admitted"] += nb
            self._stats["prefill_batches"] += 1
        return nb

    def _import_handoff(self, i: int, hand: KVHandoff,
                        now: float) -> None:
        """Bind a migrated prefill: allocate exactly its page count,
        scatter the shipped blocks into this engine's pool (a
        block-table rewrite — page ids change, intra-page offsets do
        not), and enter decode directly: ``prefill_pos`` stays -1, the
        prompt never replays."""
        if not self._alloc_pages(i, hand.n_pages):
            raise RuntimeError(
                "page reservation failed after admission check")
        raw = np.asarray(self.slot_pages[i], np.int32)
        padded = self._pad_pids(raw)
        b = len(padded)
        self._count_retrace("handoff_scatter", b)

        def _pad_rows(d, axis):
            # repeat the last shipped block out to the bucket width: the
            # duplicate page ids then write identical data, so the scatter
            # stays deterministic while the XLA shape stays bucketed
            n = d.shape[axis]
            if n == b:
                return d
            last = d[-1:] if axis == 0 else d[:, -1:]
            return np.concatenate([d, np.repeat(last, b - n, axis=axis)],
                                  axis=axis)

        pids = jnp.asarray(padded)
        self.cache = _map_cache(
            lambda l, d: l.at[pids].set(jnp.asarray(_pad_rows(d, 0), l.dtype)),
            lambda l, d: l.at[:, pids].set(
                jnp.asarray(_pad_rows(d, 1), l.dtype)),
            self.cache, hand.pages)
        req = hand.request
        self.slots[i] = req
        self.lengths[i] = hand.length
        self.last_tok[i] = hand.last_tok
        self.prefill_pos[i] = -1
        self.slot_prompt[i] = None
        self.slot_keys[i] = hand.slot_key
        self.slot_temp[i] = hand.temperature
        self.slot_topk[i] = hand.top_k
        req.state = RequestState.RUNNING
        if req.admitted_at is None:
            req.admitted_at = now
        with self._lock:
            self._stats["handoffs_imported"] += 1
            self._stats["handoff_bytes_imported"] += hand.kv_bytes

    def _prefill_step(self) -> bool:
        """Spend up to ``prefill_chunk_tokens`` prompt tokens across the
        slots still prefilling: ONE jitted ragged chunk forward appends
        each participating row's next chunk at its own cache offset
        (inert rows ride with ``chunk_lens == 0``).  Rows whose prompt
        completes sample their first token here and hand off to decode."""
        taking: Dict[int, int] = {}
        budget = (self.prefill_chunk_tokens if self.prefill_chunk_tokens
                  is not None else self.max_len)
        used = 0
        for i, req in enumerate(self.slots):
            if req is None or self.prefill_pos[i] < 0 or used >= budget:
                continue
            take = min(req.prompt_len - int(self.prefill_pos[i]),
                       budget - used)
            if take > 0:
                taking[i] = take
                used += take
        if not taking:
            return False
        # bucket the chunk width so jit retraces stay bounded; rows not
        # taking tokens this step ride with chunk_lens 0 (inert in the
        # ragged kernel — no writes, zero output)
        T = _bucket(max(taking.values()))
        tokens = np.zeros((self.max_slots, T), np.int32)
        base = np.zeros(self.max_slots, np.int32)
        clens = np.zeros(self.max_slots, np.int32)
        for i, take in taking.items():
            pos = int(self.prefill_pos[i])
            tokens[i, :take] = self.slot_prompt[i][pos:pos + take]
            base[i] = pos
            clens[i] = take
        if self.paged:
            # bucket the table to the PREFILLING rows' own page frontier
            # (base + chunk), not the global pages-in-use: tying the
            # prefill shape to other slots' decode growth would recompile
            # mid-serve whenever an admission lands on a grown pool
            need = max(-(-(int(base[i]) + take) // self.page_size)
                       for i, take in taking.items())
            mb = min(_bucket(need, lo=1), self.max_pages)
            self._count_retrace("prefill", (T, mb))
            bt = jnp.asarray(self.block_table[:, :mb])
        else:
            self._count_retrace("prefill", (T,))
            bt = None
        prefill = self._get_prefill(T)
        next_tok, last_logits, self.cache = prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(base),
            jnp.asarray(clens), self.cache, bt)
        done = [i for i, take in taking.items()
                if int(self.prefill_pos[i]) + take
                >= self.slots[i].prompt_len]
        for i, take in taking.items():
            self.prefill_pos[i] += take
        # first token for rows that just finished their prompt:
        # per-request sampling params + the slot's seeded stream
        # (all-greedy rows keep the chunk step's argmax — no sampler call)
        if done:
            if any(self.slot_temp[i] > 0 for i in done):
                first_tok, new_keys = self._sample(
                    last_logits, jnp.asarray(self.slot_keys),
                    jnp.asarray(self.slot_temp),
                    jnp.asarray(self.slot_topk))
                toks = np.asarray(first_tok)
                new_keys = np.asarray(new_keys)
                for i in done:
                    if self.slot_temp[i] > 0:
                        self.slot_keys[i] = new_keys[i]
            else:
                toks = np.asarray(next_tok)
            now = time.time()
            for i in done:
                req = self.slots[i]
                self.lengths[i] = req.prompt_len
                self.prefill_pos[i] = -1
                self.slot_prompt[i] = None
                req.first_token_at = now
                tok = int(toks[i])
                req.tokens.append(tok)
                req.token_times.append(now)
                self.last_tok[i] = tok
                if self._should_stop(req, tok, int(self.lengths[i])):
                    self._finish_slot(i, RequestState.DONE)
                elif self.prefill_only:
                    self._export_slot(i)
        with self._lock:
            self._stats["prefill_chunks"] += 1
            self._stats["prefill_tokens"] += used
        return True

    def step(self) -> bool:
        """Admit what fits, spend one bounded prefill chunk, then run one
        fused decode over every slot whose prefill already finished.
        Returns False when there was nothing to do."""
        inj = rfaults.active()
        if inj is not None and self.has_work():
            # chaos site (FaultPlan.crash_engine): only steps with work
            # count, so the Nth firing is a logical point in the
            # workload, not a function of idle-spin timing
            act = inj.fire("engine.step", engine=self.uid)
            if act is not None and act.get("action") == "crash":
                raise rfaults.InjectedFault(
                    f"injected crash at {self.uid} step")
        progressed = self._admit() > 0
        progressed = self._prefill_step() or progressed
        if self.paged:
            self._ensure_decode_pages()
        active = np.array([r is not None and self.prefill_pos[i] < 0
                           for i, r in enumerate(self.slots)])
        if not active.any():
            return progressed
        sampling = bool((self.slot_temp[active] > 0).any())
        args = (self.params, jnp.asarray(self.last_tok), self.cache,
                jnp.asarray(self.lengths), jnp.asarray(active),
                jnp.asarray(self.slot_keys), jnp.asarray(self.slot_temp),
                jnp.asarray(self.slot_topk))
        if self.paged:
            # bucket the block table (and with it the kernel grid) to the
            # pages actually in use — short sequences never pay max_len
            mb = min(_bucket(max(len(p) for p in self.slot_pages), lo=1),
                     self.max_pages)
            self._count_retrace("decode", (mb, sampling))
            # mid-prefill slots hold REAL allocated pages but must not
            # decode: mask their table rows to the sentinel so the decode
            # step's junk appends drop instead of clobbering their prompt
            bt_step = self.block_table[:, :mb].copy()
            bt_step[~active] = self.num_pages
            args = args + (jnp.asarray(bt_step),)
        else:
            self._count_retrace("decode", (self.max_len, sampling))
        next_tok, new_keys, self.cache = self._decode(*args,
                                                      sampling=sampling)
        toks = np.asarray(next_tok)
        self.slot_keys = np.array(new_keys)  # writable copy
        self.lengths = self.lengths + active.astype(np.int32)
        # memory-per-token accounting (what the serving benchmark reports):
        # paged holds only its allocated pages, contiguous always holds the
        # full [max_slots, max_len] rows
        bytes_now = (self.pages_in_use() * self._page_bytes if self.paged
                     else self._cache_bytes)
        with self._lock:
            self._stats["decode_steps"] += 1
            self._stats["decode_slot_steps"] += int(active.sum())
            self._stats["kv_bytes_step_sum"] += bytes_now
            self._stats["kv_tokens_step_sum"] += int(
                self.lengths[active].sum())
        generated = 0
        now = time.time()
        for i, req in enumerate(self.slots):
            if req is None or not active[i]:
                continue
            tok = int(toks[i])
            req.tokens.append(tok)
            req.token_times.append(now)
            self.last_tok[i] = tok
            generated += 1
            if self._should_stop(req, tok, int(self.lengths[i])):
                self._finish_slot(i, RequestState.DONE)
        if generated:
            self._bump("tokens_generated", generated)
        return True

    def run_until_drained(self, max_steps: int = 100_000) -> None:
        """Synchronous drive: step until queue and slots are empty."""
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} steps")

    # -- service-stage body --------------------------------------------------

    def run_service(self, control: Optional[ServiceControl] = None,
                    resume_state: Any = None) -> Dict[str, Any]:
        """Long-running service loop (the body of a ``service=True`` stage).

        Pulls requests from the control inbox, steps the engine, and
        cooperates with the runtime: ``stop()`` exits immediately,
        ``drain()`` exits once every accepted request finished, and a
        preemption request checkpoints + yields via ServicePreempted.
        """
        if resume_state is not None:
            self.restore(resume_state)
            self._bump("resumes")
        if self.cache is None:
            self._init_state()
        while True:
            if control is not None:
                for req in control.take_requests():
                    self.submit(req)
                if control.stop_requested():
                    # hard stop: sweep any request that raced in after the
                    # take above, then fail everything outstanding so
                    # Request.wait() callers are released, not hung
                    for req in control.take_requests():
                        self.submit(req)
                    self._fail_outstanding("service stopped before completion")
                    break
                if control.preempt_requested():
                    self._bump("preemptions")  # before the snapshot
                    # so the count survives restore()
                    state = self.checkpoint()
                    self._release_state()
                    raise ServicePreempted(state)
            if not self.step():
                if control is None:
                    break
                if (control.drain_requested()
                        and control.pending_requests() == 0):
                    break
                control.wait_for_work(self.idle_wait_s)
        return self.stats()

    # -- reporting -----------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        # the router's admission signals (queue depth/age, free pages,
        # occupancy) are snapshotted under ONE _lock acquisition so they
        # are mutually consistent
        with self._lock:
            out = dict(self._stats)
            now = time.time()
            queued = len(self.queue)
            oldest = (now - min(_entry_submitted_at(e)
                                for e in self.queue)
                      if self.queue else 0.0)
            free_pages = len(self.free_pages) if self.paged else 0
            occupied = sum(r is not None for r in self.slots)
        in_use = self.num_pages - free_pages
        out.update({
            "engine": self.uid,
            "max_slots": self.max_slots,
            "max_len": self.max_len,
            "continuous": self.continuous,
            "prefill_only": self.prefill_only,
            "kv_layout": "paged" if self.paged else "contiguous",
            "prefill_chunk_tokens": self.prefill_chunk_tokens,
            "prefill_fns_cached": len(self._prefill_fns),
            "queued": queued,
            "queue_depth": queued,
            "oldest_queued_age_s": oldest,
            "occupied": occupied,
            "kv_cache_bytes": (in_use * self._page_bytes
                               if self.paged else self._cache_bytes),
            "kv_cache_capacity_bytes": (
                self.num_pages * self._page_bytes if self.paged
                else self._cache_bytes),
        })
        if self.paged:
            out.setdefault("peak_pages", 0)
            out.update({
                "page_size": self.page_size,
                "num_pages": self.num_pages,
                "pages_in_use": in_use,
                "free_pages": free_pages,
                "kv_cache_peak_bytes": (out.get("peak_pages", 0)
                                        * self._page_bytes),
            })
        out.setdefault("retraces", 0)
        d = out.get("decode_steps", 0)
        out["slot_occupancy"] = (
            out.get("decode_slot_steps", 0) / (d * self.max_slots)
            if d else 0.0)
        # mean cache bytes held per live token across decode steps — the
        # memory-efficiency figure the serving benchmark asserts on
        out["kv_bytes_per_token"] = (
            out.get("kv_bytes_step_sum", 0)
            / max(out.get("kv_tokens_step_sum", 0), 1))
        return out

    def reset_stats(self) -> None:
        with self._lock:
            self._stats = collections.defaultdict(int)
