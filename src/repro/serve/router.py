"""EngineRouter: fleet serving over N ServeEngines (ROADMAP item 2).

One router owns a shared request queue in front of N engines and admits
**load-aware**: each route reads the target's
:meth:`~repro.serve.engine.ServeEngine.admission_signals` — slot
occupancy, page-pool pressure, queue depth and age, all snapshotted
under the engine's ``_lock`` — and sends the request to the engine with
the most headroom, holding it in the router queue when every engine is
saturated (backpressure instead of queue-stuffing the least-bad victim).

Engines run as *service bodies* in one of two modes:

* **thread mode** (default): the router spawns one thread per engine
  running ``engine.run_service(control)``.  Rolling restarts reuse the
  engine's preemption machinery: the router takes the engine out of
  rotation, re-routes its queued-but-unbound work to siblings, requests
  preemption (the engine checkpoints bound slots + pages and raises
  :class:`~repro.core.task.ServicePreempted`), and immediately resumes
  it from that checkpoint — bound requests continue mid-generation,
  bitwise-identical to an undisturbed run (tests/test_fleet.py).
* **pilot mode**: pass a :class:`~repro.core.pilot.PilotManager`; each
  engine is placed on its **own pilot** via a
  :class:`~repro.core.session.PlacementPolicy` (default
  :class:`~repro.core.session.KindAwarePlacement`, i.e.
  ``PilotManager.place``) and submitted as a ``service=True`` task on a
  per-pilot :class:`~repro.core.agent.RemoteAgent`.  When the agent
  preempts an engine for higher-priority work, the router's monitor
  notices the stalled service and re-routes its control inbox and
  engine queue to siblings; the checkpointed bound slots resume in
  place when the agent re-launches the service.

**Prefill/decode disaggregation**: engines constructed with
``prefill_only=True`` (role ``"prefill"``) run the ragged chunked
prefill and export each finished prompt as a
:class:`~repro.serve.handoff.KVHandoff` — the request plus exactly the
page blocks its block-table row points at.  The router harvests these
and ships them to a decode engine **through the Transport**
(:meth:`~repro.core.transport.Transport.submit`); the decode engine
scatters the blocks into its own pool and rewrites a fresh block-table
row.  Bytes on the wire are bounded by the pages the migrating request
owns — never the pool.

**Resilience** (``policy=``): constructing the router with a
:class:`~repro.core.resilience.policy.FailurePolicy` gives every
thread-mode member a
:class:`~repro.core.resilience.policy.CircuitBreaker` and turns engine
crashes from terminal into recoverable.  A crash recovers the engine's
outstanding work (bound requests reset and re-enter as prompts; queued
entries and parked handoffs move back verbatim), re-routes it through
the rolling-restart requeue path, and restarts the engine with fresh
state; after ``eject_after`` consecutive faults the breaker opens and
the member receives no traffic until, ``probation_s`` later, a single
probe request is routed to it — the probe finishing DONE re-admits the
member (and records the crash→re-admission latency in ``stats()``),
anything else re-ejects it.  All breaker/probe state is visible in
:meth:`stats` and :meth:`admission_signals`; zero requests are lost or
duplicated across the cycle (tests/test_resilience.py).
"""
from __future__ import annotations

import collections
import functools
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.common.params import init_params
from repro.configs.base import ModelConfig, RunConfig
from repro.core.agent import RemoteAgent
from repro.core.pilot import PilotManager
from repro.core.resilience.faults import InjectedFault
from repro.core.resilience.policy import CircuitBreaker, FailurePolicy
from repro.core.session import KindAwarePlacement, PlacementPolicy
from repro.core.task import ServiceControl, ServicePreempted, TaskDescription, TaskState
from repro.core.transport import InProcessTransport, Transport
from repro.serve.engine import ServeEngine
from repro.serve.handoff import KVHandoff, maybe_fail_delivery
from repro.serve.request import Request, RequestState
from repro.train.state import model_specs


def _ship_wire(hand: KVHandoff) -> KVHandoff:
    """Shipping body for remote transports: runs inside a worker process,
    so the handoff's page blocks are serialized across the process
    boundary on the way in and bitwise back out (KVHandoff.__getstate__
    lowers page leaves to numpy).  Today's single-host stand-in for the
    cross-node data plane; the router binds the round-tripped pages to
    the client-held request parent-side."""
    return hand


class _Member:
    """One engine in the fleet: its control handle plus how it runs
    (thread mode or a service task on a per-pilot agent)."""

    def __init__(self, engine: ServeEngine, role: str,
                 breaker: Optional[CircuitBreaker] = None):
        self.engine = engine
        self.role = role  # "any" | "prefill" | "decode"
        self.control = ServiceControl()
        self.draining = False  # guarded-by router._cond (out of rotation)
        self.error: Optional[str] = None
        self.result: Optional[Dict[str, Any]] = None
        # resilience (router policy mode): the breaker gates traffic
        # after crashes; probe_req is the request whose completion
        # decides re-admission
        self.breaker = breaker
        self.probe_req: Optional[Request] = None  # guarded-by router._cond
        self.crashes = 0  # guarded-by router._cond
        self.crashed_at: Optional[float] = None  # guarded-by router._cond
        # thread mode
        self.thread: Optional[threading.Thread] = None
        self.paused = threading.Event()  # set while checkpointed (restart)
        self.resume = threading.Event()
        # pilot mode
        self.agent: Optional[RemoteAgent] = None
        self.pilot = None
        self.task = None

    def serving(self) -> bool:
        """True when the engine body is actually running (not preempted,
        not checkpoint-paused, not crashed)."""
        if self.error is not None:
            return False
        if self.thread is not None:
            return self.thread.is_alive() and not self.paused.is_set()
        if self.task is not None:
            return self.task.state is TaskState.RUNNING
        return False


class EngineRouter:
    """Shared-queue, load-aware front of a ServeEngine fleet."""

    def __init__(self, engines: Sequence[ServeEngine], *,
                 roles: Optional[Sequence[str]] = None,
                 transport: Optional[Transport] = None,
                 manager: Optional[PilotManager] = None,
                 placement: Optional[PlacementPolicy] = None,
                 num_devices: int = 1, group: Optional[str] = None,
                 priority: int = 0, poll_s: float = 0.002,
                 engine_queue_bound: Optional[int] = None,
                 policy: Optional[FailurePolicy] = None):
        if not engines:
            raise ValueError("need at least one engine")
        roles = list(roles) if roles is not None else [
            "prefill" if e.prefill_only else "any" for e in engines]
        if len(roles) != len(engines):
            raise ValueError("roles must parallel engines")
        for e, r in zip(engines, roles):
            if e.prefill_only != (r == "prefill"):
                raise ValueError(
                    f"engine {e.uid}: role {r!r} does not match "
                    f"prefill_only={e.prefill_only}")
        if any(r == "prefill" for r in roles) and not any(
                r in ("decode", "any") for r in roles):
            raise ValueError("prefill engines need a decode target")
        # a FailurePolicy turns engine crashes from terminal into
        # recoverable: each member gets a circuit breaker (thread mode —
        # pilot-mode restarts stay agent-driven through the task policy)
        self.policy = policy
        self.members = [
            _Member(e, r,
                    breaker=(CircuitBreaker(policy.eject_after,
                                            policy.probation_s)
                             if policy is not None else None))
            for e, r in zip(engines, roles)]
        self._own_transport = transport is None
        self._transport = (transport if transport is not None
                           else InProcessTransport(max_workers=2,
                                                   thread_name_prefix="rc-router"))
        self._manager = manager
        self._placement = placement or KindAwarePlacement()
        self._num_devices = num_devices
        self._group = group
        self._priority = priority
        self.poll_s = poll_s
        self._engine_queue_bound = engine_queue_bound
        # _cond guards the router's shared state: the queue, stats, and
        # lifecycle flags below (submitters, the route loop, and
        # rolling_restart callers all touch them)
        self._cond = threading.Condition()
        self.queue: Deque[Any] = collections.deque()  # guarded-by: _cond
        self._stats: Dict[str, Any] = collections.defaultdict(int)  # guarded-by: _cond
        self._requests: List[Request] = []  # guarded-by: _cond
        # crash -> re-admission latencies ({"engine", "recovery_s"})
        self._recoveries: List[Dict[str, Any]] = []  # guarded-by: _cond
        self._stop = False  # guarded-by: _cond
        self._started = False
        self._router_thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "EngineRouter":
        if self._started:
            return self
        self._started = True
        if self._manager is not None:
            self._start_pilot_mode()
        else:
            for m in self.members:
                m.thread = threading.Thread(
                    target=self._serve_loop, args=(m,),
                    name=f"rc-{m.engine.uid}", daemon=True)
                m.thread.start()
        self._router_thread = threading.Thread(
            target=self._route_loop, name="rc-router", daemon=True)
        self._router_thread.start()
        return self

    def _start_pilot_mode(self) -> None:
        from repro.core.pipeline import Stage  # local: avoid import cycle
        used: List[Any] = []
        for m in self.members:
            stage = Stage(name=f"serve.{m.engine.uid}",
                          fn=m.engine.run_service, kind="inference",
                          num_devices=self._num_devices, service=True)
            pilots = [p for p in self._manager.pilots if p not in used]
            pilot = self._placement.place_stage(
                stage, manager=self._manager, pilots=pilots)
            if pilot is None:
                raise RuntimeError(
                    f"no free pilot for engine {m.engine.uid} "
                    f"({len(used)} already placed)")
            used.append(pilot)
            agent = RemoteAgent(pilot, max_workers=2)
            engine = m.engine

            def body(comm, *, control, resume_state=None, _e=engine):
                return _e.run_service(control, resume_state=resume_state)

            desc = TaskDescription(
                name=stage.name, fn=body, kind="inference",
                num_devices=self._num_devices, service=True,
                group=self._group, priority=self._priority)
            m.control = desc.control
            m.agent, m.pilot = agent, pilot
            [m.task] = agent.submit_async([desc])

    def _serve_loop(self, m: _Member) -> None:
        """Thread-mode engine body: run_service, pausing through the
        checkpoint/restore cycle on each rolling restart.

        With a router :class:`FailurePolicy` installed, a crash is
        *recoverable*: outstanding work is recovered
        (:meth:`ServeEngine.recover_outstanding`) and re-routed through
        the same requeue path a rolling restart uses, the member's
        circuit breaker counts the fault, and the engine restarts
        immediately with fresh state — the breaker, not the thread,
        decides when it sees traffic again (ejected members idle until
        a probationary probe re-admits them)."""
        state = None
        while True:
            try:
                m.result = m.engine.run_service(m.control, resume_state=state)
                return
            except ServicePreempted as e:
                state = e.state
                m.control._clear_preempt()
                m.paused.set()
                m.resume.wait()  # noqa: TMO001 — parked until restart; close() always sets resume
                m.resume.clear()
                m.paused.clear()
            except Exception as e:  # noqa: BLE001 — isolation boundary:
                # a crashed engine must release its waiters, not hang them
                if m.breaker is None:
                    m.error = f"{type(e).__name__}: {e}"
                    m.engine._fail_outstanding(
                        f"engine {m.engine.uid} crashed: {m.error}")
                    return
                recovered = (m.control.take_requests()
                             + m.engine.recover_outstanding())
                with self._cond:
                    m.crashes += 1
                    if m.crashed_at is None:
                        m.crashed_at = time.time()
                    m.probe_req = None  # a bound probe died with the state
                    self._stats["engine_crashes"] += 1
                    self._stats[f"crashes.{m.engine.uid}"] += 1
                    self._stats["requests_recovered"] += len(recovered)
                self._requeue(recovered)
                if m.breaker.record_fault():
                    with self._cond:
                        self._stats["ejections"] += 1
                state = None  # fresh slot state on restart

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Stop routing and the engines; unrouted requests FAIL (use
        ``drain`` first for a graceful shutdown)."""
        with self._cond:
            if self._stop:
                return
            self._stop = True
            unrouted, self.queue = list(self.queue), collections.deque()
            self._cond.notify_all()
        for entry in unrouted:
            req = entry.request if isinstance(entry, KVHandoff) else entry
            req._finish(RequestState.FAILED, "router stopped before routing")
        if self._router_thread is not None:
            self._router_thread.join(timeout)
        for m in self.members:
            m.control.stop()
            m.resume.set()  # unblock a checkpoint-paused thread
        for m in self.members:
            if m.thread is not None:
                m.thread.join(timeout)
            if m.agent is not None:
                m.agent.close(timeout=timeout)
        if self._own_transport:
            self._transport.shutdown(wait=True)

    def __enter__(self) -> "EngineRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- client side ---------------------------------------------------------

    def submit(self, request, **kw) -> Request:
        """Queue a request with the router (a :class:`Request` or a raw
        prompt array); it is routed to an engine as capacity allows."""
        if not isinstance(request, Request):
            request = Request(np.asarray(request, np.int32), **kw)
        with self._cond:
            if self._stop:
                raise RuntimeError("router is stopped")
            self.queue.append(request)
            self._requests.append(request)
            self._stats["submitted"] += 1
            self._cond.notify_all()
        return request

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until every request submitted so far reached a terminal
        state; False on timeout.  The router keeps accepting new work —
        call :meth:`close` afterwards for shutdown."""
        deadline = None if timeout is None else time.time() + timeout
        with self._cond:
            reqs = list(self._requests)
        for r in reqs:
            left = (None if deadline is None
                    else max(0.0, deadline - time.time()))
            if not r.wait(left):
                return False
        with self._cond:  # prune: drained requests need no tracking
            self._requests = [q for q in self._requests if not q.done()]
        return True

    def rolling_restart(self, index: int, timeout: float = 60.0) -> None:
        """Restart one engine from checkpoint, mid-stream: take it out
        of rotation, re-route its queued-but-unbound work to siblings,
        checkpoint it through the preemption path (bound slots, pages,
        PRNG keys), and resume it from that checkpoint.  Bound requests
        continue exactly where they stopped."""
        m = self.members[index]
        if m.thread is None:
            raise RuntimeError(
                "rolling_restart drives the thread-mode preemption cycle; "
                "in pilot mode restarts are agent-driven")
        with self._cond:
            m.draining = True
        self._requeue(m.control.take_requests() + m.engine.steal_queued())
        m.control.request_preempt()
        if not m.paused.wait(timeout):
            with self._cond:
                m.draining = False
            raise TimeoutError(
                f"engine {m.engine.uid} did not checkpoint in {timeout}s")
        with self._cond:
            self._stats["restarts"] += 1
            m.draining = False
        m.resume.set()

    def stats(self) -> Dict[str, Any]:
        with self._cond:
            out: Dict[str, Any] = dict(self._stats)
            out["router_queue"] = len(self.queue)
            out["recoveries"] = [dict(r) for r in self._recoveries]
        out["engines"] = [m.engine.stats() for m in self.members]
        if self.policy is not None:
            out["breakers"] = {m.engine.uid: m.breaker.snapshot()
                               for m in self.members
                               if m.breaker is not None}
        for key in ("tokens_generated", "completed", "failed",
                    "handoffs_exported", "handoffs_imported"):
            out[f"fleet_{key}"] = sum(s.get(key, 0) for s in out["engines"])
        return out

    def admission_signals(self) -> List[Dict[str, Any]]:
        """Per-member routing view: the engine's own admission signals
        plus the router-side state that gates them (role, draining,
        serving, breaker snapshot, probe-in-flight)."""
        sigs: List[Dict[str, Any]] = []
        for m in self.members:
            sig = m.engine.admission_signals()
            with self._cond:
                sig["draining"] = m.draining
                sig["probe_inflight"] = m.probe_req is not None
                sig["crashes"] = m.crashes
            sig["role"] = m.role
            sig["serving"] = m.serving()
            sig["error"] = m.error
            if m.breaker is not None:
                sig["breaker"] = m.breaker.snapshot()
            sigs.append(sig)
        return sigs

    # -- routing core --------------------------------------------------------

    def _route_loop(self) -> None:
        while True:
            with self._cond:
                if self._stop:
                    return
            progressed = self._harvest_handoffs()
            progressed = self._pump() or progressed
            progressed = self._monitor() or progressed
            with self._cond:
                if self._stop:
                    return
                if not progressed:
                    # idle or backpressured (every engine saturated):
                    # wait for submissions/capacity instead of spinning
                    # on admission signals
                    self._cond.wait(self.poll_s)

    def _bound(self, m: _Member) -> int:
        """Max entries allowed to wait at one engine (its queue plus its
        control inbox) — small, so load stays in the router queue where
        it can still be steered."""
        return (self._engine_queue_bound if self._engine_queue_bound
                else max(2, m.engine.max_slots))

    def _candidates(self, entry) -> List[_Member]:
        want = "decode" if isinstance(entry, KVHandoff) else "prefill"
        with self._cond:
            live = [m for m in self.members
                    if not m.draining and m.error is None and m.serving()
                    and (m.breaker is None or m.breaker.state == "closed")]
        exact = [m for m in live if m.role == want]
        return exact or [m for m in live if m.role == "any"]

    def _pick_probe(self, entry) -> Optional[_Member]:
        """An ejected member due its probationary health check: route
        this entry to it as the probe.  ``breaker.admit()`` grants at
        most one probe per probation window, and the probe's terminal
        state (watched by :meth:`_monitor`) decides re-admission."""
        want = "decode" if isinstance(entry, KVHandoff) else "prefill"
        for m in self.members:
            if m.breaker is None or m.error is not None:
                continue
            if m.role not in (want, "any") or not m.serving():
                continue
            with self._cond:
                if m.draining or m.probe_req is not None:
                    continue
            if m.breaker.state != "closed" and m.breaker.admit():
                return m
        return None

    def _pick(self, entry) -> Optional[_Member]:
        """Best engine for this entry by admission signals, or None when
        every candidate is at its backlog bound (backpressure)."""
        best, best_score = None, None
        for m in self._candidates(entry):
            sig = m.engine.admission_signals()
            backlog = sig["queue_depth"] + m.control.pending_requests()
            if backlog >= self._bound(m):
                continue
            score = (sig["max_slots"] - sig["occupied"] - backlog,
                     sig["free_pages"] / max(sig["num_pages"], 1),
                     -sig["oldest_queued_age_s"])
            if best_score is None or score > best_score:
                best, best_score = m, score
        return best

    def _pump(self) -> bool:
        """Route as much of the shared queue as current capacity admits;
        what does not fit stays queued, in order."""
        with self._cond:
            pending, self.queue = list(self.queue), collections.deque()
        kept: List[Any] = []
        routed = 0
        for entry in pending:
            probe_m = self._pick_probe(entry)
            m = probe_m if probe_m is not None else self._pick(entry)
            if m is None:
                kept.append(entry)
                continue
            if probe_m is not None:
                req = entry.request if isinstance(entry, KVHandoff) else entry
                with self._cond:
                    m.probe_req = req
                    self._stats["probes_routed"] += 1
            if isinstance(entry, KVHandoff):
                # the page blocks cross engines through the transport —
                # the data plane a cross-node fabric will replace
                if getattr(self._transport, "remote", False):
                    # subprocess transport: the pages are pickled into a
                    # worker process and back (a real process-boundary
                    # crossing), then bound parent-side in on_done — a
                    # bound method cannot cross the pickle boundary
                    self._transport.submit(
                        _ship_wire, entry,
                        on_done=functools.partial(self._deliver_shipped,
                                                  hand=entry, m=m))
                else:
                    self._transport.submit(self._deliver, entry, m)
                routed += 1
                continue
            try:
                m.control.submit_request(entry)
            except RuntimeError:
                if probe_m is not None:
                    self._probe_failed(m)
                kept.append(entry)  # raced a drain/stop: hold and re-pick
                continue
            routed += 1
            with self._cond:
                self._stats["routed"] += 1
                self._stats[f"routed_to.{m.engine.uid}"] += 1
        if kept:
            with self._cond:
                # new arrivals landed behind these in wall-clock order
                self.queue = collections.deque(kept + list(self.queue))
        return routed > 0

    def _deliver_shipped(self, fut, hand: KVHandoff, m: _Member) -> None:
        """Remote-transport delivery: bind the wire-round-tripped handoff
        (whose page bytes crossed the process boundary) to the
        client-held Request and deliver it.  A worker crash mid-ship
        loses nothing — the original handoff is still parent-side and is
        simply re-queued for another route."""
        try:
            shipped = fut.result()  # noqa: TMO001 — done-callback: result is ready
        except Exception:  # noqa: BLE001 — WorkerCrashed/RemoteTaskError
            self._requeue([hand])
            return
        # the request replica that rode the wire is discarded: the
        # client streams from the object it holds
        shipped.request = hand.request
        with self._cond:
            self._stats["handoff_wire_roundtrips"] += 1
        self._deliver(shipped, m)

    def _deliver(self, hand: KVHandoff, m: _Member) -> None:
        """Transport-side delivery of one migrated prefill.  Both an
        injected delivery failure (``FaultPlan.fail_handoff``) and a
        drain race leave the handoff intact parent-side — it is
        re-queued for another route, never lost."""
        try:
            maybe_fail_delivery(hand)
            m.control.submit_request(hand)
        except (InjectedFault, RuntimeError) as e:
            injected = isinstance(e, InjectedFault)
            was_probe = False
            with self._cond:
                if injected:
                    self._stats["handoff_faults"] += 1
                if m.probe_req is hand.request:
                    m.probe_req = None
                    was_probe = True
                    self._stats["probes_failed"] += 1
            if m.breaker is not None and (injected or was_probe):
                m.breaker.record_fault()
            self._requeue([hand])
            return
        with self._cond:
            self._stats["handoffs_routed"] += 1
            self._stats["handoff_bytes"] += hand.kv_bytes
            self._stats["handoff_pages"] += hand.n_pages

    def _probe_failed(self, m: _Member) -> None:
        """A probe could not run or came back FAILED: re-eject (the
        breaker reopens and restarts its probation window)."""
        with self._cond:
            m.probe_req = None
            self._stats["probes_failed"] += 1
        m.breaker.record_fault()

    def _harvest_handoffs(self) -> bool:
        """Collect exported prefills into the shared queue (they route
        to decode engines like any other entry, but ship via the
        transport)."""
        got = False
        for m in self.members:
            if not m.engine.prefill_only:
                continue
            hands = m.engine.take_handoffs()
            if hands:
                with self._cond:
                    self.queue.extend(hands)
                    self._cond.notify_all()
                got = True
        return got

    def _monitor(self) -> bool:
        """Re-route work stranded at an engine that is not serving
        (preempted by its agent, or checkpoint-paused): its control
        inbox and unbound engine queue move back to the shared queue.
        Bound slots ride the engine's checkpoint and resume in place."""
        moved = False
        for m in self.members:
            if m.serving() or m.error is not None:
                continue
            if m.thread is not None and not m.paused.is_set():
                continue  # thread mode: only a checkpoint pause stalls
            stolen = m.control.take_requests() + m.engine.steal_queued()
            if stolen:
                self._requeue(stolen)
                with self._cond:
                    self._stats["rerouted"] += len(stolen)
                moved = True
        moved = self._resolve_probes() or moved
        return moved

    def _resolve_probes(self) -> bool:
        """Settle finished probationary probes: DONE re-admits the
        member (breaker closes, recovery latency recorded), FAILED
        re-ejects it for another probation round."""
        resolved = False
        for m in self.members:
            with self._cond:
                pr = m.probe_req
            if pr is None or not pr.done():
                continue
            resolved = True
            if pr.state is RequestState.DONE:
                m.breaker.record_success()
                with self._cond:
                    m.probe_req = None
                    self._stats["readmissions"] += 1
                    if m.crashed_at is not None:
                        self._recoveries.append({
                            "engine": m.engine.uid,
                            "recovery_s": time.time() - m.crashed_at,
                        })
                        m.crashed_at = None
            else:
                self._probe_failed(m)
        return resolved

    def _requeue(self, entries: List[Any]) -> None:
        if not entries:
            return
        with self._cond:
            self.queue.extend(entries)
            self._cond.notify_all()


def build_fleet(cfg: ModelConfig, run_cfg: Optional[RunConfig] = None, *,
                num_engines: int, disaggregate: bool = False,
                num_prefill: Optional[int] = None, params: Any = None,
                seed: int = 0, name_prefix: str = "fleet",
                router_kwargs: Optional[Dict[str, Any]] = None,
                prefill_overrides: Optional[Dict[str, Any]] = None,
                **engine_kwargs) -> EngineRouter:
    """Construct N engines sharing one parameter set and wrap them in a
    router.  ``disaggregate=True`` splits roles: ``num_prefill``
    (default N//2, floored at 1) prefill-only engines feed the rest via
    KV handoff.

    Prefill engines default to WHOLE-PROMPT prefill
    (``prefill_chunk_tokens=None``): chunking exists to bound the decode
    stalls a long admission inflicts on in-flight tails, and a
    prefill-specialised engine has no decode tails to protect — capping
    its per-step prompt budget would only throttle the fleet's prefill
    capacity (and TTFT) for nothing.  ``prefill_overrides`` replaces the
    per-role kwarg overlay for prefill engines."""
    if num_engines < 1:
        raise ValueError("need num_engines >= 1")
    if params is None:
        params = init_params(jax.random.PRNGKey(seed), model_specs(cfg))
    engines: List[ServeEngine] = []
    if disaggregate:
        if num_engines < 2:
            raise ValueError("disaggregation needs >= 2 engines")
        np_ = (num_prefill if num_prefill is not None
               else max(1, num_engines // 2))
        if not 0 < np_ < num_engines:
            raise ValueError(f"num_prefill={np_} must leave >= 1 decode "
                             f"engine out of {num_engines}")
        pre_kw = dict(engine_kwargs)
        pre_kw.update({"prefill_chunk_tokens": None}
                      if prefill_overrides is None else prefill_overrides)
        for i in range(num_engines):
            pre = i < np_
            engines.append(ServeEngine(
                cfg, run_cfg, params=params, prefill_only=pre,
                name=f"{name_prefix}.{'pre' if pre else 'dec'}{i}",
                **(pre_kw if pre else engine_kwargs)))
        roles = ["prefill" if i < np_ else "decode"
                 for i in range(num_engines)]
    else:
        for i in range(num_engines):
            engines.append(ServeEngine(
                cfg, run_cfg, params=params,
                name=f"{name_prefix}.eng{i}", **engine_kwargs))
        roles = ["any"] * num_engines
    return EngineRouter(engines, roles=roles, **(router_kwargs or {}))
